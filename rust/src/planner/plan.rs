//! Alg. 3 — brute-force model partitioning.
//!
//! Candidate stage time bounds `t^c` are all contiguous-layer window sums
//! of `t̂f + t̂b` (O(L̂²) values). For each bound, layers are greedily
//! grouped left-to-right (Eq. 16: minimize P subject to per-stage time
//! <= t^c), then Alg. 2 scores the partition; the global argmax over
//! `R_F` wins. Runs once before the pipeline starts (O(L̂³) overall).

use super::costmodel::PipeConfig;
use super::profile::{Partition, Profile};
use super::search::{search, SearchOutcome};
use crate::util::Fnv;

/// Result of Alg. 3: the chosen partition + configuration.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub partition: Partition,
    pub config: PipeConfig,
    pub rate: f64,
    pub mem_bytes: f64,
    pub feasible: bool,
    /// the winning stage time bound
    pub tc: u64,
}

impl PlanOutcome {
    /// Content hash of the plan the engine will actually execute (see
    /// [`plan_content_id`]).
    pub fn plan_id(&self) -> u64 {
        plan_content_id(&self.partition, &self.config, self.tc)
    }
}

/// Stable content identity of a (partition, configuration) pair: equal
/// plans hash equal across runs, processes, and platforms, so trace
/// replay can detect plan churn by comparing ids alone. Hashes exactly
/// the fields the engine executes — stage bounds, per-worker
/// delay/recompute/accum/omit, and the winning stage bound `tc` — not
/// the scores (`rate`/`mem_bytes`), which are derived.
pub fn plan_content_id(partition: &Partition, config: &PipeConfig, tc: u64) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(partition.bounds.len() as u64);
    for &b in &partition.bounds {
        h.write_u64(b as u64);
    }
    h.write_u64(config.workers.len() as u64);
    for w in &config.workers {
        h.write_i64(w.delay);
        h.write(&[w.recompute as u8]);
        h.write_u64(w.accum.len() as u64);
        for &a in &w.accum {
            h.write_u64(a);
        }
        h.write_u64(w.omit.len() as u64);
        for &o in &w.omit {
            h.write_u64(o);
        }
    }
    h.write_u64(tc);
    h.finish()
}

/// Greedy consecutive grouping under a per-stage time bound.
fn group_layers(prof: &Profile, tc: u64) -> Partition {
    let mut bounds = vec![0usize];
    let mut tsum = 0u64;
    for i in 0..prof.num_layers() {
        let t = prof.t_f[i] + prof.t_b[i];
        if tsum + t > tc && tsum > 0 {
            bounds.push(i);
            tsum = t;
        } else {
            tsum += t;
        }
    }
    bounds.push(prof.num_layers());
    Partition { bounds }
}

/// Alg. 3 `plan(·)`.
pub fn plan(prof: &Profile, td: u64, budget_bytes: f64, decay: f64) -> PlanOutcome {
    // all contiguous window sums of (tf + tb)
    let l = prof.num_layers();
    let mut candidates: Vec<u64> = Vec::with_capacity(l * (l + 1) / 2);
    for i in 0..l {
        let mut sum = 0u64;
        for j in i..l {
            sum += prof.t_f[j] + prof.t_b[j];
            candidates.push(sum);
        }
    }
    candidates.sort_unstable();
    candidates.dedup();

    let mut best: Option<(PlanOutcome, SearchOutcome)> = None;
    let mut seen: Vec<Vec<usize>> = Vec::new();
    for &tc in &candidates {
        let part = group_layers(prof, tc);
        if seen.contains(&part.bounds) {
            continue; // same grouping as a smaller tc
        }
        seen.push(part.bounds.clone());
        let s = search(&part, prof, td, budget_bytes, decay);
        let better = match &best {
            None => true,
            Some((b, _)) => match (s.feasible, b.feasible) {
                (true, false) => true,
                (false, true) => false,
                (true, true) => s.rate > b.rate,
                (false, false) => s.mem_bytes < b.mem_bytes,
            },
        };
        if better {
            best = Some((
                PlanOutcome {
                    partition: part,
                    config: s.config.clone(),
                    rate: s.rate,
                    mem_bytes: s.mem_bytes,
                    feasible: s.feasible,
                    tc,
                },
                s,
            ));
        }
    }
    best.expect("at least one candidate partition").0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof() -> Profile {
        Profile {
            t_f: vec![30, 10, 10, 10, 40],
            t_b: vec![60, 20, 20, 20, 80],
            w: vec![3000, 500, 500, 500, 4000],
            a: vec![160, 80, 80, 80, 200],
        }
    }

    #[test]
    fn grouping_respects_bound() {
        let p = prof();
        // tc = 90: layer0 (90) | layers1-3 (30+30+30=90) | layer4 (120>90 alone)
        let part = group_layers(&p, 90);
        assert_eq!(part.bounds, vec![0, 1, 4, 5]);
        for j in 0..part.num_stages() {
            let t = part.stage_tf(&p, j) + part.stage_tb(&p, j);
            // every stage fits the bound except unavoidable single layers
            assert!(t <= 120, "stage {j}: {t}");
        }
        // giant bound -> single stage
        assert_eq!(group_layers(&p, 10_000).bounds, vec![0, 5]);
        // tiny bound -> per-layer
        assert_eq!(group_layers(&p, 1).bounds, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn plan_unconstrained_prefers_finer_pipeline() {
        let p = prof();
        let out = plan(&p, p.default_td(), f64::INFINITY, 1e-4);
        assert!(out.feasible);
        assert!(out.partition.validate(5));
        assert!(out.rate > 0.0);
        // under no memory pressure the planner picks more than one stage
        // (pipelining strictly improves throughput)
        assert!(out.partition.num_stages() >= 2, "{:?}", out.partition);
    }

    #[test]
    fn plan_meets_budget_and_degrades_gracefully() {
        let p = prof();
        let unconstrained = plan(&p, p.default_td(), f64::INFINITY, 1e-4);
        for frac in [0.5, 0.25, 0.1] {
            let budget = unconstrained.mem_bytes * frac;
            let out = plan(&p, p.default_td(), budget, 1e-4);
            if out.feasible {
                assert!(out.mem_bytes <= budget + 1e-9, "frac {frac}");
                assert!(out.rate <= unconstrained.rate + 1e-12);
            }
        }
    }

    #[test]
    fn plan_rate_monotone_in_budget_property() {
        crate::util::property("plan_monotone", 10, |rng| {
            let layers = 2 + rng.below(4);
            let p = Profile {
                t_f: (0..layers).map(|_| 5 + rng.below(40) as u64).collect(),
                t_b: (0..layers).map(|_| 10 + rng.below(80) as u64).collect(),
                w: (0..layers).map(|_| 200 + rng.below(4000)).collect(),
                a: (0..layers).map(|_| 16 + rng.below(400)).collect(),
            };
            let td = p.default_td();
            let max = plan(&p, td, f64::INFINITY, 1e-4);
            let half = plan(&p, td, max.mem_bytes * 0.5, 1e-4);
            if half.feasible {
                assert!(half.rate <= max.rate + 1e-12);
                assert!(half.mem_bytes <= max.mem_bytes * 0.5 + 1e-9);
            }
        });
    }

    #[test]
    fn plan_id_is_content_determined() {
        let p = prof();
        let a = plan(&p, p.default_td(), f64::INFINITY, 1e-4);
        let b = plan(&p, p.default_td(), f64::INFINITY, 1e-4);
        assert_eq!(a.plan_id(), b.plan_id(), "same inputs, same id");
        let half = plan(&p, p.default_td(), a.mem_bytes * 0.25, 1e-4);
        if half.partition.bounds != a.partition.bounds || half.config != a.config {
            assert_ne!(half.plan_id(), a.plan_id(), "different plan, different id");
        }
    }

    #[test]
    fn plan_on_real_zoo_models() {
        let zoo = crate::config::zoo::default_zoo().unwrap();
        for name in ["mlp", "convnet10", "resnet11"] {
            let spec = zoo.model(name).unwrap();
            let prof = Profile::analytic(spec, zoo.batch);
            let out = plan(&prof, prof.default_td(), f64::INFINITY, 1e-4);
            assert!(out.feasible, "{name}");
            assert!(out.partition.validate(spec.num_layers()), "{name}");
            assert!(out.config.active_workers() >= 1, "{name}");
        }
    }
}
