//! Model partitioning and pipeline planning (paper §5.2).
//!
//! - [`profile`]  — per-layer forward/backward costs, parameter and
//!   activation sizes (`profile(·)` in Alg. 3): analytic FLOPs model by
//!   default, live PJRT micro-profiling optionally.
//! - [`costmodel`] — closed-form adaptation rate `R_F` (Eq. 3) and memory
//!   footprint `M_F` (Eq. 4) of a (partition, configuration) pair.
//! - [`search`]  — Alg. 2: greedy iterative configuration search applying
//!   S1–S4 until the memory budget is met.
//! - [`plan`]    — Alg. 3: brute-force enumeration of stage time bounds,
//!   greedy consecutive-layer grouping, global argmax over `R_F`.

pub mod costmodel;
pub mod plan;
pub mod profile;
pub mod search;

pub use costmodel::{mem_footprint, adaptation_rate, PipeConfig, WorkerCfg};
pub use plan::{plan, plan_content_id, PlanOutcome};
pub use profile::{Partition, Profile};
pub use search::{search, SearchOutcome};
