//! Layer profiling and model partitions.
//!
//! `Profile` carries the paper's per-layer statistics (t̂f_i, t̂b_i, |ŵ_i|,
//! |â_i|); `Partition` is the scheme `L` (stage boundaries over layers).
//!
//! Virtual-time unit: 1 tick. The analytic profile converts FLOPs to ticks
//! at `FLOPS_PER_TICK`, making every run deterministic; `measured` profiles
//! the real PJRT executables instead (used by the §Perf pass).

use crate::backend::Backend;
use crate::config::ModelSpec;
use crate::model::LayerParams;
use crate::util::Rng;

/// Analytic cost conversion: FLOPs per virtual tick. With batch-16 dense
/// layers this puts stage times in the 1e2–1e4 tick range.
pub const FLOPS_PER_TICK: f64 = 1024.0;

/// Per-layer statistics (the `profile(·)` of Alg. 3).
#[derive(Debug, Clone)]
pub struct Profile {
    /// forward time per layer, ticks
    pub t_f: Vec<u64>,
    /// backward time per layer, ticks
    pub t_b: Vec<u64>,
    /// parameter count per layer
    pub w: Vec<usize>,
    /// output-activation count per layer (per microbatch, all samples)
    pub a: Vec<usize>,
}

impl Profile {
    /// Analytic profile from layer shapes (deterministic default).
    pub fn analytic(spec: &ModelSpec, batch: usize) -> Self {
        let layers = spec.layers();
        Profile {
            t_f: layers
                .iter()
                .map(|l| (l.fwd_flops(batch) as f64 / FLOPS_PER_TICK).ceil().max(1.0) as u64)
                .collect(),
            t_b: layers
                .iter()
                .map(|l| (l.bwd_flops(batch) as f64 / FLOPS_PER_TICK).ceil().max(1.0) as u64)
                .collect(),
            w: layers.iter().map(|l| l.param_count()).collect(),
            a: layers.iter().map(|l| l.act_count() * batch).collect(),
        }
    }

    /// Measured profile: micro-benchmark each layer's fwd/bwd through a
    /// backend (PJRT for the real artifacts), converting wall-clock ns to
    /// ticks so relative stage costs reflect the deployed executables.
    pub fn measured(backend: &dyn Backend, spec: &ModelSpec, batch: usize, reps: u32) -> Self {
        let layers = spec.layers();
        let mut rng = Rng::new(0xBEEF);
        let mut t_f = Vec::new();
        let mut t_b = Vec::new();
        for l in &layers {
            let p = LayerParams::init(l, &mut rng);
            let x: Vec<f32> = (0..batch * l.in_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let g: Vec<f32> = (0..batch * l.out_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            // warmup (compile)
            let _ = backend.dense_fwd(l, &p, &x, batch);
            let _ = backend.dense_bwd(l, &p, &x, &g, batch);
            // ferret-lint: allow(det-time) — measured profiling is wall-clock by design; planning from it is still replayable via the recorded Profile
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                let _ = backend.dense_fwd(l, &p, &x, batch);
            }
            let fwd_ns = t0.elapsed().as_nanos() as u64 / reps as u64;
            // ferret-lint: allow(det-time) — measured profiling is wall-clock by design; planning from it is still replayable via the recorded Profile
            let t1 = std::time::Instant::now();
            for _ in 0..reps {
                let _ = backend.dense_bwd(l, &p, &x, &g, batch);
            }
            let bwd_ns = t1.elapsed().as_nanos() as u64 / reps as u64;
            // 1 tick = 1 microsecond of measured time
            t_f.push((fwd_ns / 1000).max(1));
            t_b.push((bwd_ns / 1000).max(1));
        }
        Profile {
            t_f,
            t_b,
            w: layers.iter().map(|l| l.param_count()).collect(),
            a: layers.iter().map(|l| l.act_count() * batch).collect(),
        }
    }

    /// Refresh per-layer times from *measured* per-stage times of a run
    /// under `part`: each stage's layer times are rescaled so their sum
    /// matches the measured stage mean (`None` keeps the analytic value).
    /// Sizes (`w`, `a`) are unchanged. Seeds mid-stream re-planning with
    /// this run's observed costs instead of the analytic FLOPs model; a
    /// lockstep run measures exactly the replayed analytic costs, so the
    /// refresh is the identity there (re-plans stay deterministic).
    pub fn rescale_stages(
        &self,
        part: &Partition,
        stage_tf: &[Option<f64>],
        stage_tb: &[Option<f64>],
    ) -> Profile {
        let mut out = self.clone();
        for j in 0..part.num_stages() {
            if let Some(m) = stage_tf.get(j).copied().flatten() {
                let a = part.stage_tf(self, j) as f64;
                if a > 0.0 {
                    for l in part.stage_layers(j) {
                        out.t_f[l] = ((self.t_f[l] as f64 * m / a).round() as u64).max(1);
                    }
                }
            }
            if let Some(m) = stage_tb.get(j).copied().flatten() {
                let a = part.stage_tb(self, j) as f64;
                if a > 0.0 {
                    for l in part.stage_layers(j) {
                        out.t_b[l] = ((self.t_b[l] as f64 * m / a).round() as u64).max(1);
                    }
                }
            }
        }
        out
    }

    pub fn num_layers(&self) -> usize {
        self.t_f.len()
    }

    /// The paper's default arrival interval: `t^d = max_i t̂f_i`.
    pub fn default_td(&self) -> u64 {
        *self.t_f.iter().max().unwrap()
    }

    pub fn total_params(&self) -> usize {
        self.w.iter().sum()
    }
}

/// A model partition scheme `L`: `bounds` has P+1 entries, stage j covers
/// layers `[bounds[j], bounds[j+1])`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub bounds: Vec<usize>,
}

impl Partition {
    /// Single-stage partition (no pipelining).
    pub fn trivial(num_layers: usize) -> Self {
        Partition { bounds: vec![0, num_layers] }
    }

    /// One stage per layer.
    pub fn per_layer(num_layers: usize) -> Self {
        Partition { bounds: (0..=num_layers).collect() }
    }

    pub fn num_stages(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn stage_layers(&self, j: usize) -> std::ops::Range<usize> {
        self.bounds[j]..self.bounds[j + 1]
    }

    /// Stage forward time: sum of its layers' t̂f.
    pub fn stage_tf(&self, prof: &Profile, j: usize) -> u64 {
        self.stage_layers(j).map(|l| prof.t_f[l]).sum()
    }

    pub fn stage_tb(&self, prof: &Profile, j: usize) -> u64 {
        self.stage_layers(j).map(|l| prof.t_b[l]).sum()
    }

    /// Pipeline-stage times: `t^f = max_j stage_tf`, `t^b = max_j stage_tb`.
    pub fn tf(&self, prof: &Profile) -> u64 {
        (0..self.num_stages()).map(|j| self.stage_tf(prof, j)).max().unwrap()
    }

    pub fn tb(&self, prof: &Profile) -> u64 {
        (0..self.num_stages()).map(|j| self.stage_tb(prof, j)).max().unwrap()
    }

    /// |w_j|: parameters of stage j.
    pub fn stage_params(&self, prof: &Profile, j: usize) -> usize {
        self.stage_layers(j).map(|l| prof.w[l]).sum()
    }

    /// |a_j|: activations of stage j (all layers).
    pub fn stage_acts(&self, prof: &Profile, j: usize) -> usize {
        self.stage_layers(j).map(|l| prof.a[l]).sum()
    }

    /// Σ|â_l| over the *internal* layers of stage j (the activations that
    /// recomputation avoids storing, Eq. 4: l in [L_j+1, L_{j+1}-1]).
    pub fn stage_internal_acts(&self, prof: &Profile, j: usize) -> usize {
        let r = self.stage_layers(j);
        if r.len() <= 1 {
            0
        } else {
            (r.start + 1..r.end).map(|l| prof.a[l]).sum()
        }
    }

    pub fn validate(&self, num_layers: usize) -> bool {
        !self.bounds.is_empty()
            && self.bounds[0] == 0
            && *self.bounds.last().unwrap() == num_layers
            && self.bounds.windows(2).all(|w| w[0] < w[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo::default_zoo;

    fn prof() -> Profile {
        Profile {
            t_f: vec![10, 20, 30, 40],
            t_b: vec![20, 40, 60, 80],
            w: vec![100, 200, 300, 400],
            a: vec![16, 32, 48, 64],
        }
    }

    #[test]
    fn analytic_profile_from_zoo() {
        let zoo = default_zoo().unwrap();
        let spec = zoo.model("mnistnet10").unwrap();
        let p = Profile::analytic(spec, zoo.batch);
        assert_eq!(p.num_layers(), spec.num_layers());
        assert!(p.t_f.iter().all(|&t| t >= 1));
        // bwd = 2x fwd flops
        for (f, b) in p.t_f.iter().zip(&p.t_b) {
            assert!(*b >= *f);
        }
        assert_eq!(p.total_params(), spec.param_count());
        assert_eq!(p.default_td(), *p.t_f.iter().max().unwrap());
    }

    #[test]
    fn rescale_stages_identity_and_scaling() {
        let p = prof();
        let part = Partition { bounds: vec![0, 2, 4] };
        // measured == analytic -> exact identity (lockstep determinism)
        let same = p.rescale_stages(
            &part,
            &[Some(30.0), Some(70.0)],
            &[Some(60.0), Some(140.0)],
        );
        assert_eq!(same.t_f, p.t_f);
        assert_eq!(same.t_b, p.t_b);
        // stage 0 measured twice as slow -> its layers double; stage 1
        // unmeasured -> untouched; sizes never change
        let scaled = p.rescale_stages(&part, &[Some(60.0), None], &[None, None]);
        assert_eq!(scaled.t_f, vec![20, 40, 30, 40]);
        assert_eq!(scaled.t_b, p.t_b);
        assert_eq!(scaled.w, p.w);
        assert_eq!(scaled.a, p.a);
        // a measurement rounding to zero is floored at 1 tick
        let floor = p.rescale_stages(&part, &[Some(0.001), None], &[None, None]);
        assert!(floor.t_f[0] >= 1 && floor.t_f[1] >= 1);
    }

    #[test]
    fn partition_stage_stats() {
        let p = prof();
        let part = Partition { bounds: vec![0, 2, 4] };
        assert!(part.validate(4));
        assert_eq!(part.num_stages(), 2);
        assert_eq!(part.stage_tf(&p, 0), 30);
        assert_eq!(part.stage_tf(&p, 1), 70);
        assert_eq!(part.tf(&p), 70);
        assert_eq!(part.tb(&p), 140);
        assert_eq!(part.stage_params(&p, 0), 300);
        assert_eq!(part.stage_acts(&p, 1), 112);
        // internal acts exclude the stage's first layer's input boundary:
        // stage 0 = layers {0,1} -> internal = a[1]
        assert_eq!(part.stage_internal_acts(&p, 0), 32);
        // single-layer stage has no internal activations
        let per = Partition::per_layer(4);
        assert_eq!(per.stage_internal_acts(&p, 2), 0);
        assert!(per.validate(4));
        assert!(Partition::trivial(4).validate(4));
        assert!(!Partition { bounds: vec![0, 0, 4] }.validate(4));
        assert!(!Partition { bounds: vec![0, 5] }.validate(4));
    }
}
