//! Alg. 2 — iterative configuration search.
//!
//! Given a partition `L` and budget `M`, start from the throughput-optimal
//! configuration and greedily deploy T2/T3/T4 (per worker/stage), always
//! applying the move with the best memory-saved-per-rate-lost ratio
//! `ΔM_F / ΔR_F^T`, until `M_F <= M`. T1 (recomputation) is handled as in
//! the paper's `search(·)`: both `c^r = 0` and `c^r = 1` searches run and
//! the feasible one with higher `R_F` wins.

use super::costmodel::{adaptation_rate, mem_footprint, PipeConfig};
use super::profile::{Partition, Profile};
use crate::util::cdiv;

/// Result of Alg. 2.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub config: PipeConfig,
    pub rate: f64,
    pub mem_bytes: f64,
    /// false when even the maximally-reduced configuration exceeds M
    pub feasible: bool,
}

/// One applicable S-move on a configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Move {
    /// S2: grow accumulation of (worker, stage) by the paper's Δc_a step
    Accum { n: usize, j: usize, to: u64 },
    /// S3: full back-propagation omission for (worker, stage)
    Omit { n: usize, j: usize },
    /// S4: remove worker n
    Remove { n: usize },
}

fn apply(cfg: &mut PipeConfig, m: Move, p: usize) {
    match m {
        Move::Accum { n, j, to } => cfg.workers[n].accum[j] = to,
        Move::Omit { n, j } => {
            cfg.workers[n].accum[j] = 1;
            cfg.workers[n].omit[j] = (p - 1 - j) as u64;
        }
        Move::Remove { n } => cfg.workers[n].delay = -1,
    }
}

/// Enumerate applicable moves per the S2/S3/S4 preconditions.
fn moves(cfg: &PipeConfig, p: usize) -> Vec<Move> {
    let mut out = Vec::new();
    for (n, w) in cfg.workers.iter().enumerate() {
        if !w.active() {
            continue;
        }
        // S4: removable when every non-final stage is omitted
        if (0..p.saturating_sub(1)).all(|j| w.omit[j] != 0) {
            out.push(Move::Remove { n });
            continue;
        }
        for j in 0..p {
            if w.omit[j] != 0 {
                continue;
            }
            let rem = (p - 1 - j) as u64;
            if rem == 0 {
                continue; // final stage: no staleness, nothing to reduce
            }
            let cur = cdiv(rem, w.accum[j]);
            if cur > 1 {
                // S2: Δc_a = ceil(rem / (cur-1)) - c_a (skips ceiling plateaus)
                let to = cdiv(rem, cur - 1);
                debug_assert!(to > w.accum[j]);
                out.push(Move::Accum { n, j, to });
            } else {
                // S3: accumulation saturated -> omit entirely
                out.push(Move::Omit { n, j });
            }
        }
    }
    out
}

/// Inner loop of Alg. 2 at a fixed `c^r`.
fn itersearch(
    part: &Partition,
    prof: &Profile,
    td: u64,
    recompute: bool,
    budget_bytes: f64,
    decay: f64,
) -> SearchOutcome {
    let p = part.num_stages();
    let (tf, tb) = (part.tf(prof), part.tb(prof));
    let mut cfg = PipeConfig::initial(p, tf, tb, recompute, td);
    let mut rate = adaptation_rate(part, prof, &cfg, decay);
    let mut mem = mem_footprint(part, prof, &cfg);
    while mem > budget_bytes {
        let mut best: Option<(f64, Move, f64, f64)> = None;
        for m in moves(&cfg, p) {
            let mut cand = cfg.clone();
            apply(&mut cand, m, p);
            let r2 = adaptation_rate(part, prof, &cand, decay);
            let m2 = mem_footprint(part, prof, &cand);
            let dm = mem - m2;
            let dr = rate - r2;
            if dm <= 0.0 {
                continue;
            }
            // maximize ΔM/ΔR; free memory (ΔR ~ 0) scores +inf
            let ratio = if dr <= 1e-15 { f64::INFINITY } else { dm / dr };
            if best.as_ref().map(|(b, ..)| ratio > *b).unwrap_or(true) {
                best = Some((ratio, m, r2, m2));
            }
        }
        match best {
            Some((_, m, r2, m2)) => {
                apply(&mut cfg, m, p);
                rate = r2;
                mem = m2;
            }
            None => {
                // fully reduced but still over budget
                return SearchOutcome { config: cfg, rate, mem_bytes: mem, feasible: false };
            }
        }
    }
    SearchOutcome { config: cfg, rate, mem_bytes: mem, feasible: true }
}

/// Alg. 2 `search(·)`: best of the `c^r ∈ {0, 1}` searches (S1).
pub fn search(
    part: &Partition,
    prof: &Profile,
    td: u64,
    budget_bytes: f64,
    decay: f64,
) -> SearchOutcome {
    let s0 = itersearch(part, prof, td, false, budget_bytes, decay);
    let s1 = itersearch(part, prof, td, true, budget_bytes, decay);
    match (s0.feasible, s1.feasible) {
        (true, false) => s0,
        (false, true) => s1,
        // both feasible: higher rate; both infeasible: lower memory
        (true, true) => {
            if s0.rate >= s1.rate {
                s0
            } else {
                s1
            }
        }
        (false, false) => {
            if s0.mem_bytes <= s1.mem_bytes {
                s0
            } else {
                s1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Partition, Profile) {
        let prof = Profile {
            t_f: vec![10, 10, 10, 10],
            t_b: vec![20, 20, 20, 20],
            w: vec![1000, 1000, 1000, 1000],
            a: vec![160, 160, 160, 160],
        };
        (Partition::per_layer(4), prof)
    }

    #[test]
    fn unconstrained_budget_keeps_initial_config() {
        let (part, prof) = setup();
        let s = search(&part, &prof, 10, f64::INFINITY, 1e-4);
        assert!(s.feasible);
        assert_eq!(s.config.active_workers(), 3);
        // no accumulation/omission deployed
        for w in &s.config.workers {
            assert!(w.accum.iter().all(|&a| a == 1));
            assert!(w.omit.iter().all(|&o| o == 0));
        }
    }

    #[test]
    fn tight_budget_is_met() {
        let (part, prof) = setup();
        let unconstrained = search(&part, &prof, 10, f64::INFINITY, 1e-4);
        let budget = unconstrained.mem_bytes * 0.4;
        let s = search(&part, &prof, 10, budget, 1e-4);
        assert!(s.feasible);
        assert!(s.mem_bytes <= budget, "{} > {budget}", s.mem_bytes);
        assert!(s.rate <= unconstrained.rate);
        assert!(s.rate > 0.0);
    }

    #[test]
    fn rate_monotone_in_budget() {
        let (part, prof) = setup();
        let max = search(&part, &prof, 10, f64::INFINITY, 1e-4).mem_bytes;
        let mut prev_rate = -1.0;
        for frac in [0.15, 0.3, 0.5, 0.75, 1.0] {
            let s = search(&part, &prof, 10, max * frac, 1e-4);
            assert!(s.feasible, "frac {frac}");
            assert!(
                s.rate >= prev_rate - 1e-12,
                "rate not monotone at {frac}: {} < {prev_rate}",
                s.rate
            );
            prev_rate = s.rate;
        }
    }

    #[test]
    fn starvation_budget_degenerates_to_zero_workers() {
        // A budget below one reduced model copy is "met" only by removing
        // every worker: feasible in M_F terms but with zero learning rate.
        let (part, prof) = setup();
        let s = search(&part, &prof, 10, 64.0, 1e-4);
        assert!(s.feasible);
        assert_eq!(s.config.active_workers(), 0);
        assert_eq!(s.rate, 0.0);
        assert_eq!(s.mem_bytes, 0.0);
    }

    #[test]
    fn property_search_never_exceeds_feasible_budget() {
        crate::util::property("search_budget", 30, |rng| {
            let layers = 2 + rng.below(5);
            let prof = Profile {
                t_f: (0..layers).map(|_| 5 + rng.below(50) as u64).collect(),
                t_b: (0..layers).map(|_| 10 + rng.below(100) as u64).collect(),
                w: (0..layers).map(|_| 100 + rng.below(5000)).collect(),
                a: (0..layers).map(|_| 16 + rng.below(500)).collect(),
            };
            let part = Partition::per_layer(layers);
            let td = prof.default_td();
            let max = search(&part, &prof, td, f64::INFINITY, 1e-4).mem_bytes;
            let budget = max * rng.uniform();
            let s = search(&part, &prof, td, budget, 1e-4);
            if s.feasible {
                assert!(s.mem_bytes <= budget + 1e-9);
            }
            // rate and memory are always non-negative
            assert!(s.rate >= 0.0);
            assert!(s.mem_bytes >= 0.0);
        });
    }

    #[test]
    fn s3_deployed_under_extreme_pressure_before_removal() {
        let (part, prof) = setup();
        // budget just above one fully-reduced worker: expect omission on
        // early stages rather than losing the last worker
        let one_worker_min = {
            let mut cfg = PipeConfig::initial(4, 10, 20, false, 10);
            cfg.workers.truncate(1);
            for j in 0..3 {
                cfg.workers[0].omit[j] = (3 - j) as u64;
                cfg.workers[0].accum[j] = 1;
            }
            mem_footprint(&part, &prof, &cfg)
        };
        let s = search(&part, &prof, 10, one_worker_min * 1.05, 1e-4);
        assert!(s.feasible);
        assert_eq!(s.config.active_workers(), 1);
        assert!(s.rate > 0.0, "still learning something");
    }
}
